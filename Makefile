# Tier-1 verification and common dev entry points.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test deps lint bench bench-engines scenarios bench-ci attack-demo \
        strategy-demo fused-demo mesh-demo test-mesh comm-demo trace-demo \
        serve-demo churn-demo

deps:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

lint:
	ruff check .

bench:
	$(PY) -m benchmarks.run --scale quick

bench-engines:
	$(PY) -m benchmarks.kernel_bench --scale full

# the registry + the CI smoke grid (mirrors the bench-smoke job's grid);
# results land under the shared output-dir convention (experiments/)
scenarios:
	$(PY) -m repro.core.scenarios --list
	$(PY) -m repro.core.scenarios --grid ci --json ci_grid.json

# the PR 4 strategy plugins end-to-end by registry name: FedProx under
# label skew + FedAdam's server optimizer over the kernel-backed
# aggregate (both also run in the CI smoke grid)
strategy-demo:
	$(PY) -m repro.core.scenarios --run fedprox-dirichlet-vec fedadam-iid-vec

# one adversarial scenario end-to-end: 25% sign-flip attackers at 32
# clients, defended by the trimmed-mean selection kernel (DESIGN.md §8;
# the full acceptance family lives in experiments/attacks/)
attack-demo:
	$(PY) -m repro.core.scenarios --run attack-signflip-trimmed-32c-vec

# the fused executor end-to-end (DESIGN.md §10): the whole run as one
# compiled lax.scan with device-resident state — first the HFL twin of
# the CI grid's iid-hfl-vec, then attack+defense running entirely
# in-scan through the bitonic selection kernel's production path
fused-demo:
	$(PY) -m repro.core.scenarios --run iid-hfl-fused \
	    attack-signflip-median-fused

# the upload-codec axis end-to-end (DESIGN.md §12): top-k + error
# feedback on the AFL star, int8 qsgd inside the fused executor, and
# the codec x adversary crossing (quantized sign-flip vs median) — each
# result document carries the byte-count "communication" block
comm-demo:
	$(PY) -m repro.core.scenarios --run comm-topk-afl-vec \
	    comm-qsgd-hfl-fused comm-qsgd-signflip-median-vec

# observability end-to-end (DESIGN.md §13): the 16-client fused
# sign-flip/median scenario with the per-phase breakdown table and the
# Chrome-trace artifact (open experiments/traces/obs_trace_fused_16c.json
# in Perfetto / chrome://tracing)
trace-demo:
	mkdir -p experiments/traces
	$(PY) examples/federated_image_classification.py \
	    --scenario obs-trace-fused-16c \
	    --trace-out experiments/traces/obs_trace_fused_16c.json

# federation-in-the-loop serving end-to-end (DESIGN.md §14): the fused
# executor with per-round models stacked in-scan and hot-swaps replayed
# at round boundaries, then burst traffic against the bounded queue
# (shedding exercised and accounted), then the codec x adversary x
# serving crossing under diurnal load — each result document carries
# the schema-v2.4 "serving" block (p50/p95/p99, shed rate, staleness)
serve-demo:
	$(PY) -m repro.core.scenarios --run serve-iid-fused serve-hfl-burst \
	    serve-qsgd-signflip-median

# churn & fault injection end-to-end (DESIGN.md §15): gossip under 30%
# crash/rejoin churn with the per-round moving-target ring, HFL under
# the mid-severity mix with a 60% quorum (held rounds exercised), and
# the headline acceptance pair — colluding sign-flip vs median where
# the re-randomized ring (fault_mtd) beats the pinned static ring.
# Each result document carries the schema-v2.5 "faults" block.
churn-demo:
	$(PY) -m repro.core.scenarios --run churn-afl-gossip-mtd \
	    churn-hfl-quorum churn-signflip-median-mtd \
	    churn-signflip-median-static

# the mesh-sharded fused executor (DESIGN.md §11): the same fused run
# single-device vs with the client axis sharded over 8 forced host
# devices (mesh_bench sets the XLA flag itself — it must precede the
# jax import, which is why this is a dedicated module, not a make var)
mesh-demo:
	$(PY) -m benchmarks.mesh_bench --devices 8 --clients 32 --rounds 4

# the sharded tier-1 subset (the CI mesh job's selection): every test
# here forks subprocesses with forced host device counts
test-mesh:
	$(PY) -m pytest -x -q tests/test_mesh_fused.py tests/test_fl_mesh_dryrun.py

# the CI round-throughput gate, locally: OVERWRITES the tracked
# BENCH_ci.json (the recorded acceptance run — only commit the change
# when deliberately re-recording) and compares against the committed
# baseline
bench-ci:
	$(PY) -m benchmarks.ci_bench --scale quick --out BENCH_ci.json \
	    --baseline benchmarks/BENCH_baseline.json --check

"""Teacher-forced sequential decode must reproduce the parallel forward
pass — validates KV caches, MLA absorbed decode, Mamba2 chunked-vs-step,
mLSTM parallel/chunked-vs-step, sLSTM, sliding-window ring caches."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model import build_model

TEXT_ARCHS = [a for a in ARCH_IDS
              if get_config(a).modality == "text"
              and not get_config(a).encoder_layers]


@pytest.mark.parametrize("arch", TEXT_ARCHS)
def test_decode_matches_parallel(arch, rng_key):
    cfg = get_config(arch).reduced(dtype="float32")
    if cfg.num_experts:
        # decode==parallel only holds drop-free: the parallel pass drops
        # tokens that overflow expert capacity, single-token decode never
        # does. capacity_factor=E makes overflow impossible for the test.
        cfg = cfg.with_updates(capacity_factor=float(cfg.num_experts))
    model = build_model(cfg)
    params = model.init(rng_key)
    B, S = 2, 16
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    logits_par, _ = model.apply(params, {"tokens": toks})

    state = model.init_decode_state(B, S)
    outs = []
    step = jax.jit(model.decode_step)
    for t in range(S):
        lg, state = step(params, state, toks[:, t:t + 1])
        outs.append(lg)
    logits_seq = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_par - logits_seq)))
    assert err < 5e-2, f"{arch}: decode/parallel mismatch {err}"


def test_sliding_window_ring_cache_matches_full(rng_key):
    """With capacity < sequence length, windowed decode must equal the
    windowed parallel attention (ring buffer correctness)."""
    cfg = get_config("gemma3-4b").reduced(
        dtype="float32", sliding_window=8, global_every=0)
    model = build_model(cfg)
    params = model.init(rng_key)
    B, S = 1, 24
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    logits_par, _ = model.apply(params, {"tokens": toks})
    state = model.init_decode_state(B, S)   # window caches are W-capped
    outs = []
    for t in range(S):
        lg, state = model.decode_step(params, state, toks[:, t:t + 1])
        outs.append(lg)
    logits_seq = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_par - logits_seq)))
    assert err < 5e-2, f"ring-cache mismatch {err}"


def test_chunked_attention_equals_einsum(rng_key):
    cfg_c = get_config("yi-9b").reduced(dtype="float32",
                                        attn_impl="chunked", attn_chunk=16)
    cfg_e = cfg_c.with_updates(attn_impl="einsum")
    mc, me = build_model(cfg_c), build_model(cfg_e)
    params = mc.init(rng_key)
    toks = jax.random.randint(rng_key, (2, 64), 0, cfg_c.vocab_size)
    lc, _ = mc.apply(params, {"tokens": toks})
    le, _ = me.apply(params, {"tokens": toks})
    assert float(jnp.max(jnp.abs(lc - le))) < 1e-3


def test_chunked_mlstm_equals_parallel(rng_key):
    cfg_c = get_config("xlstm-125m").reduced(dtype="float32",
                                             mlstm_impl="chunked",
                                             mlstm_chunk=8)
    cfg_p = cfg_c.with_updates(mlstm_impl="parallel")
    mc, mp = build_model(cfg_c), build_model(cfg_p)
    params = mc.init(rng_key)
    toks = jax.random.randint(rng_key, (2, 32), 0, cfg_c.vocab_size)
    lc, _ = mc.apply(params, {"tokens": toks})
    lp, _ = mp.apply(params, {"tokens": toks})
    assert float(jnp.max(jnp.abs(lc - lp))) < 1e-3

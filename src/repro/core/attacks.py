"""Byzantine client attacks — the adversarial workload axis (DESIGN.md §8).

A configurable subset of clients is adversarial. Model-poisoning attacks
corrupt the client's trained parameters *between local training and the
aggregation event*; the data-poisoning attack (label_flip) corrupts the
client's shard before training. All corruptions are expressed relative to
`base` — the model the client pulled at the start of its local round — so
they target the *update* theta_c - base, which is what aggregation acts on:

  sign_flip      theta_mal = base - scale * (theta_c - base)
                 (gradient reversal: the update is flipped and boosted)
  gauss          theta_mal = theta_c + scale * N(0, I)
                 (additive Gaussian noise on the uploaded parameters)
  model_replace  theta_mal = base + scale * (theta_c - base)
                 (boosted model replacement, Bagdasaryan et al. 2020: a
                 large `scale` makes the single malicious update dominate
                 the average)
  label_flip     data-layer: shard labels y -> (num_classes - 1) - y
                 before training (the uploaded parameters are an honest
                 SGD run on poisoned data — `corrupt_tree` is identity)

RNG-parity contract (DESIGN.md §4): corruption must be identical under
`engine="loop"` and `engine="vectorized"`. Two mechanisms guarantee that:

* the attacker set is drawn from a dedicated generator derived from the
  config seed (`attacker_ids`) — never from the schedule rng;
* Gaussian noise is keyed by (seed, aggregation event, absolute client
  id) through `jax.random.fold_in`, so the noise a client injects does
  not depend on which engine materializes it or on how the event's
  client subset is ordered.

`corrupt_tree` is the single-client corruption (traceable — used inside
the CFL `lax.scan`); `corrupt_stacked` is its vmap over the leading
client axis (the stacked engine path). The loop engine calls
`corrupt_tree` per attacker with the same key derivation, so both
engines see bitwise-identical corruption.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl_types import ATTACKS

Params = Any

_ATTACK_SALT = 0x5EED_A77C        # decouples attack keys from model init
NUM_CLASSES = 10


def attacker_ids(num_clients: int, fraction: float, seed: int,
                 placement: str = "random") -> np.ndarray:
    """The Byzantine subset: `fraction` of the federation, rng-chosen from
    a generator derived from (seed, salt) so the schedule rng (participant
    sampling, visit orders, speeds) is untouched. At least one attacker
    when fraction > 0; at least one honest client always.

    `placement="colluding"` packs the attackers on even client ids
    instead (0, 2, 4, ...): under a degree-2 static ring every odd
    victim's neighborhood {c-1, c, c+1} then holds two attackers — the
    coordinated-neighborhood adversary that captures a per-neighborhood
    median, and the baseline the moving-target topology re-randomization
    is measured against (DESIGN.md §15)."""
    if fraction <= 0 or num_clients <= 1:
        return np.empty((0,), int)
    k = min(num_clients - 1, max(1, int(round(fraction * num_clients))))
    if placement == "colluding":
        # deterministic: evens first, then odds if the fraction exceeds
        # half the federation (keeps the count identical to "random")
        order = list(range(0, num_clients, 2)) + \
            list(range(1, num_clients, 2))
        return np.sort(np.asarray(order[:k], int))
    if placement != "random":
        raise ValueError(f"unknown attack placement {placement!r} "
                         f"(expected 'random' or 'colluding')")
    rng = np.random.default_rng([seed, _ATTACK_SALT])
    return np.sort(rng.choice(num_clients, size=k, replace=False))


def attacker_mask(num_clients: int, fraction: float, seed: int,
                  placement: str = "random") -> np.ndarray:
    mask = np.zeros((num_clients,), bool)
    mask[attacker_ids(num_clients, fraction, seed, placement)] = True
    return mask


def flip_labels(labels: np.ndarray, num_classes: int = NUM_CLASSES
                ) -> np.ndarray:
    """Deterministic label flip y -> (K-1) - y (an involution, so the
    attack is its own inverse — pinned in tests)."""
    return (num_classes - 1 - labels).astype(labels.dtype)


def event_key(seed: int, event: int) -> jax.Array:
    """PRNG key for one aggregation event (sync round / async batch)."""
    return jax.random.fold_in(
        jax.random.PRNGKey(np.uint32(seed ^ _ATTACK_SALT)), event)


def client_keys(key: jax.Array, client_ids) -> jax.Array:
    """Per-client keys from absolute ids — subset/order independent."""
    ids = jnp.asarray(np.asarray(client_ids, np.int64) & 0x7FFFFFFF,
                      jnp.int32)
    return jax.vmap(lambda c: jax.random.fold_in(key, c))(ids)


@partial(jax.jit, static_argnames=("kind",))
def corrupt_tree(local: Params, base: Params, flag, key, *, kind: str,
                 scale) -> Params:
    """One client's corruption. `flag` (bool scalar, may be a tracer)
    gates the attack; honest clients pass through unchanged. `key` seeds
    the gauss noise (per-leaf via fold_in). Traceable, so it composes
    with `lax.scan` (the vectorized CFL pass corrupts in-scan)."""
    if kind not in ATTACKS:
        raise ValueError(f"unknown attack {kind!r} (expected {ATTACKS})")
    if kind in ("none", "label_flip"):      # label_flip acts at data layer
        return local
    scale = jnp.asarray(scale, jnp.float32)
    flag = jnp.asarray(flag, bool)
    leaves, treedef = jax.tree_util.tree_flatten(local)
    base_leaves = jax.tree_util.tree_flatten(base)[0]
    out = []
    for i, (l, b) in enumerate(zip(leaves, base_leaves)):
        l32, b32 = l.astype(jnp.float32), b.astype(jnp.float32)
        if kind == "sign_flip":
            atk = b32 - scale * (l32 - b32)
        elif kind == "model_replace":
            atk = b32 + scale * (l32 - b32)
        else:                               # gauss
            noise = jax.random.normal(jax.random.fold_in(key, i), l.shape,
                                      jnp.float32)
            atk = l32 + scale * noise
        out.append(jnp.where(flag, atk, l32).astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


@partial(jax.jit, static_argnames=("kind",))
def corrupt_stacked(stacked: Params, base_stacked: Params, flags,
                    keys, *, kind: str, scale) -> Params:
    """Vectorized corruption over the leading client axis: row c of every
    leaf is corrupted iff flags[c], with noise keyed by keys[c] (derive
    via `client_keys` from absolute ids for engine parity)."""
    return jax.vmap(
        lambda l, b, f, k: corrupt_tree(l, b, f, k, kind=kind, scale=scale)
    )(stacked, base_stacked, jnp.asarray(flags, bool), keys)


def corrupt_clients(client_params: Sequence[Params],
                    base_params: Sequence[Params],
                    client_ids: Sequence[int], mask: np.ndarray, *,
                    kind: str, scale: float, seed: int, event: int,
                    ) -> list:
    """Loop-engine helper: corrupt a *list* of client pytrees in place of
    the stacked path. `base_params` is the per-client list of round-start
    models (same length as `client_params` — repeat a shared model
    explicitly; sniffing a single pytree here would misread list-rooted
    params); `mask` is indexed by absolute client id. The key derivation
    matches `corrupt_stacked` exactly (parity contract)."""
    if kind in ("none", "label_flip") or not np.any(mask):
        return list(client_params)
    if len(base_params) != len(client_params):
        raise ValueError(
            f"base_params must list one round-start model per client "
            f"({len(base_params)} != {len(client_params)})")
    key = event_key(seed, event)
    out = []
    for p, b, c in zip(client_params, base_params, client_ids):
        if mask[c]:
            ck = jax.random.fold_in(key, int(c) & 0x7FFFFFFF)
            p = corrupt_tree(p, b, True, ck, kind=kind, scale=scale)
        out.append(p)
    return out

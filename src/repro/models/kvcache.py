"""Dense KV caches for autoregressive decode.

Two variants:
* full cache     — (B, S_max, Hk, dh) per layer; for full/global attention.
* window cache   — (B, W, Hk, dh) ring buffer; for sliding-window layers
                   (gemma3 local layers): O(W) memory regardless of context.

Caches are plain pytrees so they flow through jit / pjit and are shardable
(batch over the FSDP axis, heads over "model" when divisible).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jnp.ndarray          # (L, B, S_cap, Hk, dh)  stacked over layers
    v: jnp.ndarray          # (L, B, S_cap, Hk, dh)
    index: jnp.ndarray      # scalar int32 — next write position (== tokens so far)
    window: int = 0         # 0 => full cache; >0 => ring buffer of this size

    @property
    def capacity(self):
        return self.k.shape[2]


def init_cache(num_layers, batch, capacity, num_kv_heads, head_dim,
               dtype=jnp.bfloat16, window=0, prefill_len=0):
    shape = (num_layers, batch, capacity, num_kv_heads, head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        index=jnp.asarray(prefill_len, jnp.int32),
        window=window,
    )


def cache_layer(cache: KVCache, layer: int):
    return cache.k[layer], cache.v[layer]


def update_layer(cache_k, cache_v, index, new_k, new_v, window=0):
    """Write one decode step (new_k/new_v: (B, 1, Hk, dh)) at `index`.

    Returns updated (cache_k, cache_v). For window caches the write position
    wraps (ring buffer).
    """
    cap = cache_k.shape[1]
    pos = jnp.where(window > 0, index % cap, index)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, new_k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, new_v, pos, axis=1)
    return cache_k, cache_v


def valid_mask(index, capacity, window=0):
    """(capacity,) bool — which cache slots hold valid, attendable entries."""
    slots = jnp.arange(capacity)
    if window > 0:
        n_valid = jnp.minimum(index + 1, capacity)
        return slots < n_valid            # ring buffer: everything written
    return slots <= index                 # linear cache: prefix

"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + MoE top-6.

[arXiv:2405.04434]  Assignment line says "MoE 64e top-6" while its bracket
note says "160 routed"; we follow the primary spec: 64 routed experts,
top-6, + 2 shared experts, per-expert d_ff=1408 (see DESIGN.md §6).
All layers MoE (the real model's single dense first layer is folded into
the MoE stack so the scan stays homogeneous; noted deviation).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    attention_kind="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
).with_updates(sharding_profile="moe")

"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Causal + sliding-window masks; fp32 accumulators. The quadratic S*T score
matrix is never materialized in HBM — each grid step streams one
(BLOCK_K, d) key/value tile through VMEM against a resident (BLOCK_Q, d)
query tile, maintaining the running (max, sum, acc) online-softmax state
in VMEM scratch. This is the standard TPU adaptation of the GPU flash
algorithm: tiles sized for the ~16 MiB VMEM and 128-aligned for the MXU
(vs. CUDA's SRAM/warp-level formulation).

Grid: (BH, n_q, n_k), k innermost so the scratch carries across k-steps
for a fixed query tile. Causal/window masking is positional; fully-masked
k-tiles are skipped via `pl.when` (no MXU work issued).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  block_q, block_k, n_k, causal, window, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip tiles that are fully masked (above the diagonal / out of window)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window and window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1
                              > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)        # (BQ, d)
        k = k_ref[...].astype(jnp.float32)        # (BK, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window and window > 0:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128, interpret=False):
    """q: (BH, S, d); k, v: (BH, T, d). Returns (BH, S, d).

    S must be a multiple of block_q, T of block_k (callers pad or fall
    back to the reference path otherwise).
    """
    BH, S, d = q.shape
    T = k.shape[1]
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q, n_k = S // block_q, T // block_k
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        causal=causal, window=window, scale=scale)

    return pl.pallas_call(
        kern,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Round-boundary model hot-swap: double-buffered published params.

The serving engine never trains and the trainer never serves — the only
coupling is `ModelBuffer`. Each round boundary the driver PUBLISHES the
freshly aggregated global model into the slot the server is NOT reading
and flips the active index; a batch dispatched before the flip keeps the
reference it acquired and completes on the old version (in-flight work
is never drained or dropped). With two slots and a single-server batch
engine at most one dispatch is ever in flight, so a publish can never
overwrite the buffer a live batch is reading — the invariant the double
buffer encodes (on device this is what makes the swap a pointer flip,
not a copy).

Staleness semantics (DESIGN.md §14): a request served from version v
that COMPLETES when version V is the latest published is V - v rounds
stale. Version r is the global model after aggregation event r; version
0 is the pre-training init (published at t=0, so serving never lacks a
model).
"""
from __future__ import annotations

import bisect
from typing import Any, List, Tuple


class ModelBuffer:
    def __init__(self):
        self._slots: List[Any] = [None, None]
        self._active = -1
        self._version = -1
        # (time, version) per publish, time-ascending — the staleness
        # ledger: latest_version_at() answers "what was current when
        # this request completed" without retaining old params
        self.publishes: List[Tuple[float, int]] = []
        self._pub_times: List[float] = []

    @property
    def swap_count(self) -> int:
        """Hot-swaps = publishes beyond the initial install."""
        return max(0, len(self.publishes) - 1)

    def publish(self, params, version: int, t: float) -> None:
        if self.publishes:
            assert t >= self.publishes[-1][0] and \
                version > self.publishes[-1][1], (t, version)
        idx = 0 if self._active < 0 else 1 - self._active
        self._slots[idx] = params
        self._active = idx
        self._version = version
        self.publishes.append((float(t), int(version)))
        self._pub_times.append(float(t))

    def acquire(self):
        """Snapshot (version, params) at dispatch time. The caller holds
        the params reference for the batch's whole service time."""
        assert self._active >= 0, "no model published yet"
        return self._version, self._slots[self._active]

    def latest_version_at(self, t: float) -> int:
        """Version current at time `t` (publishes at exactly `t` count)."""
        i = bisect.bisect_right(self._pub_times, t)
        assert i > 0, "queried before the initial publish"
        return self.publishes[i - 1][1]

"""End-to-end behaviour tests: the paper's full measurement pipeline on
CPU — metrics, the CNN learning the synthetic datasets, and all three FL
strategies improving over initialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fl_types import FLConfig
from repro.core.metrics import Timer, classification_metrics, confusion_matrix
from repro.core.simulation import FederatedSimulation
from repro.data.synthetic import fashion_like, mnist_like


# -- metrics (paper Eqs. 1-4) -------------------------------------------------

def test_confusion_matrix():
    cm = confusion_matrix([0, 0, 1, 1], [0, 1, 1, 1], 2)
    np.testing.assert_array_equal(cm, [[1, 1], [0, 2]])


def test_classification_metrics_hand_computed():
    y_true = [0, 0, 0, 1, 1, 2]
    y_pred = [0, 0, 1, 1, 1, 0]
    m = classification_metrics(y_true, y_pred, 3)
    assert abs(m["accuracy"] - 4 / 6) < 1e-9
    # class precisions: 0: 2/3, 1: 2/3, 2: 0 -> macro 4/9
    assert abs(m["precision"] - (2 / 3 + 2 / 3 + 0) / 3) < 1e-9
    # class recalls: 0: 2/3, 1: 1.0, 2: 0 -> macro 5/9
    assert abs(m["recall"] - (2 / 3 + 1.0 + 0) / 3) < 1e-9
    assert m["balanced_accuracy"] == m["recall"]


def test_perfect_prediction_metrics():
    y = list(range(10)) * 3
    m = classification_metrics(y, y, 10)
    for k in ("accuracy", "precision", "recall", "f1"):
        assert m[k] == 1.0


def test_timer():
    import time
    with Timer() as t:
        time.sleep(0.01)
    assert t.elapsed >= 0.01


# -- e2e FL on synthetic data ---------------------------------------------------

@pytest.fixture(scope="module")
def small_ds():
    return mnist_like(seed=1, n_train=600, n_test=200)


@pytest.mark.parametrize("strategy", ["hfl", "afl", "cfl"])
def test_strategy_learns(strategy, small_ds):
    fl = FLConfig(strategy=strategy, num_clients=4, num_groups=2, rounds=3,
                  local_epochs=2, local_batch_size=32, lr=0.04, seed=0,
                  hfl_global_every=1, participation=1.0)
    r = FederatedSimulation(fl, small_ds).run()
    assert r.test_accuracy > 0.25, f"{strategy} failed to beat chance x2.5"
    assert r.build_time_s > 0 and r.classification_time_s > 0
    assert 0 <= r.f1 <= 1 and 0 <= r.precision <= 1
    assert r.confusion.sum() == 200
    assert len(r.round_train_acc) == 3


def test_cfl_beats_hfl(small_ds):
    """The paper's headline ordering at small scale (C1)."""
    res = {}
    for s in ("hfl", "cfl"):
        fl = FLConfig(strategy=s, num_clients=4, num_groups=2, rounds=3,
                      local_epochs=1, local_batch_size=32, lr=0.04, seed=0)
        res[s] = FederatedSimulation(fl, small_ds).run().test_accuracy
    assert res["cfl"] > res["hfl"]


def test_results_deterministic(small_ds):
    fl = FLConfig(strategy="afl", num_clients=4, rounds=2, num_groups=2,
                  local_epochs=1, local_batch_size=32, seed=5)
    r1 = FederatedSimulation(fl, small_ds).run()
    r2 = FederatedSimulation(fl, small_ds).run()
    assert r1.test_accuracy == r2.test_accuracy
